"""Shared test helpers: parameter/theta initialization mirroring the Rust
coordinator, and the LET fusion reference used by the equivalence tests."""

import numpy as np
import jax.numpy as jnp

from compile import layouts
from compile.configs import ModelConfig, QuantSetting
from compile.kernels import ref


def init_block(cfg: ModelConfig, rng: np.random.Generator) -> dict:
    """Random block weights with a couple of planted outlier channels so the
    LET machinery has something to fix (synthetic stand-in for the trained
    statistics the paper relies on)."""
    bw = {}
    for name, shape in cfg.block_params():
        if name.startswith("ln") and name.endswith("_w"):
            v = np.ones(shape, np.float32) + 0.1 * rng.standard_normal(shape).astype(np.float32)
            # plant a few outlier channels: trained LLMs (esp. the OPT
            # family) develop LayerNorm weights that blow up specific
            # channels — the systematic activation outliers LET targets.
            idx = rng.choice(shape[0], max(2, shape[0] // 32), replace=False)
            v[idx] *= rng.uniform(4.0, 8.0, idx.shape).astype(np.float32)
        elif name.startswith("b") or name.endswith("_b"):
            v = np.zeros(shape, np.float32)
        else:
            fan_in = shape[0]
            v = (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)
            # heavy-tail a few weight columns (outlier-correlated weights).
            idx = rng.choice(shape[1], max(1, shape[1] // 32), replace=False)
            v[:, idx] *= 4.0
        bw[name] = jnp.asarray(v)
    return bw


def pack_block(cfg, bw):
    lay = layouts.block_layout(cfg)
    return jnp.concatenate([jnp.reshape(bw[n], (-1,)) for (n, _, _, _) in lay])


def init_theta(cfg: ModelConfig, qs: QuantSetting, rng, variant="lwc", scale=0.1) -> dict:
    """Near-identity theta: gamma/beta logits at 4.0 (sigmoid ~ 0.982),
    LET scales ~ 1, shifts ~ 0, with optional random perturbation."""
    th = {}
    for name, shape in layouts.theta1_shapes(cfg, qs, variant):
        if variant == "lwc":
            v = np.full(shape, 4.0, np.float32)
        elif variant == "pact":
            v = np.full(shape, -3.0 if "tmin" in name else 3.0, np.float32)
        else:  # lsq
            qmax = 2.0**qs.wbits - 1.0
            v = (np.full(shape, np.log(6.0 / qmax), np.float32)
                 if "logh" in name else np.full(shape, qmax / 2.0, np.float32))
        th[name] = jnp.asarray(v)
    for name, shape in layouts.theta2_shapes(cfg):
        v = (scale * rng.standard_normal(shape)).astype(np.float32)
        th[name] = jnp.asarray(v)
    return th


def pack_theta(cfg, qs, th, variant="lwc"):
    lay = layouts.theta_layout(cfg, qs, variant)
    return jnp.concatenate([jnp.reshape(th[n], (-1,)) for (n, _, _, _) in lay])


def init_model_flat(cfg: ModelConfig, rng: np.random.Generator):
    parts = []
    for name, shape in cfg.model_params():
        base = name.split(".")[-1]
        if base.startswith("ln") and base.endswith("_w") or base == "lnf_w":
            v = np.ones(shape, np.float32)
        elif base.startswith("b") or base.endswith("_b"):
            v = np.zeros(shape, np.float32)
        elif base in ("embed", "pos_embed", "head"):
            v = (0.02 * rng.standard_normal(shape)).astype(np.float32)
        else:
            v = (rng.standard_normal(shape) / np.sqrt(shape[0])).astype(np.float32)
        parts.append(v.reshape(-1))
    return jnp.asarray(np.concatenate(parts))


def fuse_reference(cfg: ModelConfig, qs: QuantSetting, bw: dict, th: dict) -> dict:
    """The LET fusion the Rust coordinator performs after calibration
    (DESIGN.md section 1): returns runtime block weights such that
    block_fwd(fused, x, abits) == calib_block_fwd(bw, th, x) given the same
    weight fake-quantization. Weight fake-quant is applied here with the
    learned gamma/beta on the *pre-column-scaled* tensors and the column
    scaling applied afterwards (asymmetric MinMax quantization is exactly
    equivariant to per-output-channel scaling)."""
    s1 = np.exp(np.asarray(th["ls1"]))
    d1 = np.asarray(th["d1"])
    s2 = np.exp(np.asarray(th["ls2"]))
    d2 = np.asarray(th["d2"])
    s3 = np.exp(np.asarray(th["ls3"]))
    d3 = np.asarray(th["d3"])
    lsa = np.asarray(th["lsa"])
    sa = np.exp(lsa)
    if cfg.family == "llama":
        h, hd = cfg.n_heads, cfg.head_dim
        sa = np.concatenate([sa.reshape(h, hd // 2)] * 2, axis=-1).reshape(cfg.d_model)

    def fq(name, w):
        return np.asarray(ref.fake_quant_lwc(
            jnp.asarray(w), th[f"{name}.gamma"], th[f"{name}.beta"], qs.wbits, qs.group))

    f = {k: np.asarray(v).copy() for k, v in bw.items()}
    wq, wk, wv, wo = (np.asarray(bw[k]) for k in ("wq", "wk", "wv", "wo"))
    # norm1 <- s1, d1
    f["ln1_w"] = np.asarray(bw["ln1_w"]) / s1
    f["ln1_b"] = (np.asarray(bw["ln1_b"]) - d1) / s1
    f["wq"] = fq("wq", s1[:, None] * wq) / sa[None, :]
    f["bq"] = (d1 @ wq + np.asarray(bw["bq"])) / sa
    f["wk"] = fq("wk", s1[:, None] * wk) * sa[None, :]
    f["bk"] = (d1 @ wk + np.asarray(bw["bk"])) * sa
    f["wv"] = fq("wv", s1[:, None] * wv) / s2[None, :]
    f["bv"] = (d1 @ wv + np.asarray(bw["bv"]) - d2) / s2
    f["wo"] = fq("wo", s2[:, None] * wo)
    f["bo"] = d2 @ wo + np.asarray(bw["bo"])
    if cfg.family == "llama":
        wg, wu, wd = (np.asarray(bw[k]) for k in ("wg", "wu", "wd"))
        f["ln2_w"] = np.asarray(bw["ln2_w"]) / s3
        f["ln2_b"] = (np.asarray(bw["ln2_b"]) - d3) / s3
        f["wg"] = fq("wg", s3[:, None] * wg)
        f["bg"] = d3 @ wg + np.asarray(bw["bg"])
        f["wu"] = fq("wu", s3[:, None] * wu)
        f["bu"] = d3 @ wu + np.asarray(bw["bu"])
        f["wd"] = fq("wd", wd)
    else:
        w1, w2 = np.asarray(bw["w1"]), np.asarray(bw["w2"])
        f["ln2_w"] = np.asarray(bw["ln2_w"]) / s3
        f["ln2_b"] = (np.asarray(bw["ln2_b"]) - d3) / s3
        f["w1"] = fq("w1", s3[:, None] * w1)
        f["b1"] = d3 @ w1 + np.asarray(bw["b1"])
        f["w2"] = fq("w2", w2)
    return {k: jnp.asarray(v) for k, v in f.items()}
