"""L2 correctness: block/model graph semantics, and the fusion-equivalence
invariant that specifies the Rust coordinator's LET fusion."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import layouts, model
from compile.configs import MODELS, QUANT_SETTINGS
from tests import util

RNG = np.random.default_rng(7)


def _x(cfg, b=2):
    return jnp.asarray(RNG.standard_normal((b, cfg.seq_len, cfg.d_model)).astype(np.float32))


@pytest.mark.parametrize("name", ["omni-test", "opt-test"])
def test_block_fwd_shapes(name):
    cfg = MODELS[name]
    bw = util.init_block(cfg, RNG)
    x = _x(cfg)
    y = model.block_fwd(cfg, bw, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


@pytest.mark.parametrize("name", ["omni-test", "opt-test"])
def test_block_fwd_actq_close_at_8bit(name):
    cfg = MODELS[name]
    bw = util.init_block(cfg, RNG)
    x = _x(cfg)
    y16 = np.asarray(model.block_fwd(cfg, bw, x, 16))
    y8 = np.asarray(model.block_fwd(cfg, bw, x, 8, use_pallas=True))
    assert np.abs(y16 - y8).max() < 0.15 * (np.abs(y16).max() + 1)


def test_block_intermediates_consistent():
    cfg = MODELS["omni-test"]
    bw = util.init_block(cfg, RNG)
    x = _x(cfg)
    outs = model.block_intermediates(cfg, bw, x)
    assert len(outs) == 8
    y = model.block_fwd(cfg, bw, x)
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(y), atol=1e-5)


@pytest.mark.parametrize("name", ["omni-test", "opt-test"])
@pytest.mark.parametrize("setting", ["w4a16", "w3a16", "w4a4", "w4a16g64"])
def test_fusion_equivalence(name, setting):
    """calib_block_fwd(W, theta) == block_fwd(fuse(W, theta)) — the central
    invariant: the error minimized during calibration is exactly the error
    of the deployed fused model. `util.fuse_reference` mirrors the Rust
    coordinator's fusion and is the spec it is tested against."""
    cfg = MODELS[name]
    qs = QUANT_SETTINGS[setting]
    bw = util.init_block(cfg, RNG)
    th = util.init_theta(cfg, qs, RNG, scale=0.15)
    x = _x(cfg)
    calib = np.asarray(model.calib_block_fwd(cfg, qs, bw, th, x, use_pallas=False))
    fused = util.fuse_reference(cfg, qs, bw, th)
    run = np.asarray(model.block_fwd(cfg, fused, x, qs.abits, use_pallas=False))
    scale = np.abs(calib).max() + 1e-6
    np.testing.assert_allclose(run / scale, calib / scale, atol=5e-3)


def test_calib_identity_theta_matches_rtn():
    """theta at init (gamma/beta logits=30 -> sigmoid=1, s=1, d=0) makes the
    calibration forward equal plain RTN fake-quant of the block."""
    cfg = MODELS["omni-test"]
    qs = QUANT_SETTINGS["w4a16"]
    bw = util.init_block(cfg, RNG)
    th = util.init_theta(cfg, qs, RNG, scale=0.0)
    for k in list(th):
        if k.endswith(".gamma") or k.endswith(".beta"):
            th[k] = jnp.full_like(th[k], 30.0)
    x = _x(cfg)
    calib = np.asarray(model.calib_block_fwd(cfg, qs, bw, th, x, use_pallas=False))
    from compile.kernels import ref
    rtn = {k: v for k, v in bw.items()}
    for nm, cin, cout in cfg.block_linears():
        rtn[nm] = ref.fake_quant_minmax(bw[nm], qs.wbits, qs.group)
    run = np.asarray(model.block_fwd(cfg, rtn, x, 16))
    np.testing.assert_allclose(run, calib, atol=2e-4, rtol=1e-3)


@pytest.mark.parametrize("setting", ["w3a16", "w4a4"])
def test_calib_gradient_descent_reduces_error(setting):
    """The property calibration relies on: AdamW on theta with the STE
    gradients reduces the block reconstruction error well below its value
    at the MinMax initialization. (Pointwise finite differences are NOT a
    valid oracle for STE gradients — the forward is a step function.)"""
    cfg = MODELS["omni-test"]
    qs = QUANT_SETTINGS[setting]
    bw = util.init_block(cfg, RNG)
    wflat = util.pack_block(cfg, bw)
    th = util.init_theta(cfg, qs, RNG, scale=0.0)
    tflat = np.asarray(util.pack_theta(cfg, qs, th))
    # outlier-y activations (what LET exists to fix)
    x = np.asarray(_x(cfg, b=2)).copy()
    idx = RNG.choice(cfg.d_model, 3, replace=False)
    x[..., idx] *= 8.0
    x = jnp.asarray(x)
    tgt = model.block_fwd(cfg, bw, x)  # FP block output (Eq. 1 target)

    step = jax.jit(lambda tf: model.calib_loss_and_grads(
        cfg, qs, "lwc", wflat, tf, x, tgt, use_pallas=False))
    m = np.zeros_like(tflat)
    v = np.zeros_like(tflat)
    losses = []
    lr = 1e-2
    for i in range(120):
        loss, g = step(jnp.asarray(tflat))
        g = np.asarray(g)
        losses.append(float(loss))
        m = 0.9 * m + 0.1 * g
        v = 0.95 * v + 0.05 * g * g
        mh = m / (1 - 0.9 ** (i + 1))
        vh = v / (1 - 0.95 ** (i + 1))
        tflat = tflat - lr * mh / (np.sqrt(vh) + 1e-8)
    best = min(losses[80:])
    assert best < 0.75 * losses[0], (losses[0], best)


def test_calib_grads_nonzero_for_all_groups():
    cfg = MODELS["omni-test"]
    qs = QUANT_SETTINGS["w4a4"]
    bw = util.init_block(cfg, RNG)
    wflat = util.pack_block(cfg, bw)
    th = util.init_theta(cfg, qs, RNG, scale=0.05)
    tflat = util.pack_theta(cfg, qs, th)
    x = _x(cfg, b=1)
    tgt = jnp.zeros_like(x)
    _, grads = model.calib_loss_and_grads(cfg, qs, "lwc", wflat, tflat, x, tgt,
                                          use_pallas=False)
    grads = np.asarray(grads)
    tlay = layouts.theta_layout(cfg, qs, "lwc")
    for (n, _, o, z) in tlay:
        g = np.abs(grads[o:o + z])
        assert g.max() > 0, f"all-zero grads for {n}"


@pytest.mark.parametrize("variant", ["pact", "lsq"])
def test_clip_variants_run_and_grad(variant):
    cfg = MODELS["omni-test"]
    qs = QUANT_SETTINGS["w3a16"]
    bw = util.init_block(cfg, RNG)
    wflat = util.pack_block(cfg, bw)
    th = util.init_theta(cfg, qs, RNG, variant=variant)
    tflat = util.pack_theta(cfg, qs, th, variant)
    x = _x(cfg, b=1)
    tgt = jnp.asarray(np.asarray(model.block_fwd(cfg, bw, x)))
    loss, grads = model.calib_loss_and_grads(cfg, qs, variant, wflat, tflat, x, tgt,
                                             use_pallas=False)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(grads)).all()
    assert np.abs(np.asarray(grads)).max() > 0


@pytest.mark.parametrize("name", ["omni-test", "opt-test"])
def test_model_nll_sane(name):
    cfg = MODELS[name]
    pflat = util.init_model_flat(cfg, RNG)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (2, cfg.seq_len)).astype(np.int32))
    nll = float(model.model_nll(cfg, pflat, tokens))
    # random init -> NLL near log(vocab)
    assert abs(nll - np.log(cfg.vocab)) < 1.0


def test_model_nll_masked_consistency():
    cfg = MODELS["omni-test"]
    pflat = util.init_model_flat(cfg, RNG)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (2, cfg.seq_len)).astype(np.int32))
    mask = jnp.ones((2, cfg.seq_len), jnp.float32)
    per_seq = np.asarray(model.model_nll_masked(cfg, pflat, tokens, mask))
    mean_nll = float(model.model_nll(cfg, pflat, tokens))
    np.testing.assert_allclose(per_seq.sum() / (2 * (cfg.seq_len - 1)), mean_nll, rtol=1e-4)


def test_train_step_learns():
    cfg = MODELS["omni-test"]
    pflat = util.init_model_flat(cfg, RNG)
    m = jnp.zeros_like(pflat)
    v = jnp.zeros_like(pflat)
    tokens = jnp.asarray(RNG.integers(0, cfg.vocab, (4, cfg.seq_len)).astype(np.int32))
    step_fn = jax.jit(lambda p, m, v, s, tok: model.train_step(cfg, p, m, v, s, 3e-3, tok))
    losses = []
    for s in range(30):
        pflat, m, v, loss = step_fn(pflat, m, v, jnp.float32(s), tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.5, losses[::10]
