"""Layout / manifest consistency tests."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import layouts
from compile.configs import MODELS, QUANT_SETTINGS


@pytest.mark.parametrize("name", list(MODELS))
def test_pack_unpack_roundtrip_block(name):
    cfg = MODELS[name]
    lay = layouts.block_layout(cfg)
    n = layouts.layout_size(lay)
    flat = jnp.arange(n, dtype=jnp.float32)
    d = layouts.unpack(flat, lay)
    back = layouts.pack(d, lay)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


@pytest.mark.parametrize("name", list(MODELS))
def test_layout_offsets_contiguous(name):
    cfg = MODELS[name]
    for lay in (layouts.block_layout(cfg), layouts.model_layout(cfg)):
        off = 0
        for (_, shape, o, z) in lay:
            assert o == off
            assert z == int(np.prod(shape)) if shape else 1
            off += z


def test_model_layout_contains_all_blocks():
    cfg = MODELS["omni-1m"]
    lay = layouts.model_layout(cfg)
    names = [n for (n, _, _, _) in lay]
    for i in range(cfg.n_layers):
        assert f"blk{i}.wq" in names
    assert names[0] == "embed"
    assert names[-1] == "head"


def test_opt_has_pos_embed_llama_does_not():
    lay_l = [n for (n, _, _, _) in layouts.model_layout(MODELS["omni-1m"])]
    lay_o = [n for (n, _, _, _) in layouts.model_layout(MODELS["opt-1m"])]
    assert "pos_embed" not in lay_l
    assert "pos_embed" in lay_o


@pytest.mark.parametrize("setting", ["w2a16", "w4a16g64", "w4a4"])
def test_theta_layout_shapes(setting):
    cfg = MODELS["omni-1m"]
    qs = QUANT_SETTINGS[setting]
    lay = layouts.theta_layout(cfg, qs)
    names = {n for (n, _, _, _) in lay}
    for (nm, cin, cout) in cfg.block_linears():
        assert f"{nm}.gamma" in names and f"{nm}.beta" in names
        shape = next(s for (n, s, _, _) in lay if n == f"{nm}.gamma")
        ng = cin // qs.group if qs.group else 1
        assert shape == (ng, cout)
    assert "lsa" in names
    sa_shape = next(s for (n, s, _, _) in lay if n == "lsa")
    assert sa_shape == (cfg.d_model // 2,)  # llama: shared across RoPE pairs


def test_group_sizes_divide_dims():
    for mname, cfg in MODELS.items():
        for qname, qs in QUANT_SETTINGS.items():
            if qs.group and (cfg.d_model % qs.group or cfg.d_ff % qs.group):
                continue  # skipped by aot.py too
            lay = layouts.theta_layout(cfg, qs)
            assert layouts.layout_size(lay) > 0
