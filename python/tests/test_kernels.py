"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes / bit-widths / group sizes; gradients of the
custom-VJP wrappers are checked against the oracle's autodiff exactly
(they are defined to be the same function).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, fake_quant, act_quant, qmatmul

RNG = np.random.default_rng(1234)


def _rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def assert_quant_close(a, b, step):
    """Quantizers computed twice with different fp instruction orderings can
    legitimately disagree by exactly one quantization step on round-to-even
    ties (1-ulp differences in the scale h). Require: almost all elements
    bit-close, and no element further apart than one step."""
    a, b = np.asarray(a), np.asarray(b)
    diff = np.abs(a - b)
    assert (diff <= np.broadcast_to(step, a.shape) * 1.01 + 1e-6).all(), diff.max()
    frac = (diff > 1e-5 * (1 + np.abs(a))).mean()
    assert frac < 5e-3, f"{frac:.4%} of elements off by a quant step"


# ---------------------------------------------------------------------------
# fake_quant_lwc
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    cin_g=st.sampled_from([(32, 0), (64, 0), (64, 32), (128, 32), (128, 64), (96, 32)]),
    cout=st.sampled_from([16, 48, 128]),
    bits=st.integers(min_value=2, max_value=8),
)
def test_fake_quant_matches_ref(cin_g, cout, bits):
    cin, group = cin_g
    ng = cin // group if group else 1
    w = _rand(cin, cout)
    gl = _rand(ng, cout)
    bl = _rand(ng, cout)
    a = ref.fake_quant_lwc(w, gl, bl, bits, group)
    b = fake_quant.fake_quant_lwc(w, gl, bl, bits, group)
    g = group if group else cin
    wg = np.asarray(w).reshape(cin // g, g, cout)
    step = ((wg.max(1) - wg.min(1)) / (2.0**bits - 1))[:, None, :]
    step = np.broadcast_to(step, wg.shape).reshape(cin, cout)
    assert_quant_close(a, b, step)


@settings(max_examples=10, deadline=None)
@given(
    bits=st.integers(min_value=2, max_value=4),
    group=st.sampled_from([0, 32]),
)
def test_fake_quant_grads_match_ref(bits, group):
    cin, cout = 64, 32
    ng = cin // group if group else 1
    w, gl, bl = _rand(cin, cout), _rand(ng, cout), _rand(ng, cout)
    ct = _rand(cin, cout)

    def loss(fn):
        return lambda *a: jnp.sum(fn(*a, bits, group) * ct)

    gr = jax.grad(loss(ref.fake_quant_lwc), argnums=(0, 1, 2))(w, gl, bl)
    gp = jax.grad(loss(fake_quant.fake_quant_lwc), argnums=(0, 1, 2))(w, gl, bl)
    for a, b in zip(gr, gp):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_fake_quant_levels_on_grid():
    """Quantized-dequantized values must lie on the (h, z) integer grid."""
    w = _rand(64, 16)
    big = jnp.full((1, 16), 30.0)
    out = np.asarray(ref.fake_quant_lwc(w, big, big, 3, 0))
    for c in range(16):
        col = out[:, c]
        assert len(np.unique(col)) <= 8  # 2^3 levels


def test_fake_quant_minmax_preserves_range():
    """With gamma = beta = 1 the extreme values survive quantization."""
    w = _rand(128, 8) * 3.0
    out = np.asarray(ref.fake_quant_minmax(w, 8, 0))
    wn = np.asarray(w)
    np.testing.assert_allclose(out.max(0), wn.max(0), atol=0.05)
    np.testing.assert_allclose(out.min(0), wn.min(0), atol=0.05)


def test_fake_quant_clipping_shrinks_range():
    """gamma, beta < 1 must clip the dequantized range."""
    w = _rand(128, 8)
    half = jnp.zeros((1, 8))  # sigmoid(0) = 0.5
    out = np.asarray(ref.fake_quant_lwc(w, half, half, 8, 0))
    wn = np.asarray(w)
    assert (out.max(0) <= 0.5 * wn.max(0) + 0.05).all()
    assert (out.min(0) >= 0.5 * wn.min(0) - 0.05).all()


def test_fake_quant_error_decreases_with_bits():
    w = _rand(256, 32)
    errs = []
    for bits in (2, 3, 4, 6, 8):
        dq = ref.fake_quant_minmax(w, bits, 0)
        errs.append(float(jnp.mean((dq - w) ** 2)))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-4


def test_groupwise_beats_per_channel():
    """Group-wise quantization must reduce (or match) quantization error."""
    w = _rand(128, 32) * jnp.asarray(RNG.uniform(0.1, 3.0, (128, 1)).astype(np.float32))
    e_pc = float(jnp.mean((ref.fake_quant_minmax(w, 3, 0) - w) ** 2))
    e_g = float(jnp.mean((ref.fake_quant_minmax(w, 3, 32) - w) ** 2))
    assert e_g <= e_pc


def test_column_scale_equivariance():
    """fq(W / s)[:, c] == fq(W)[:, c] / s_c — the property that makes the
    Rust LET fusion exact (DESIGN.md section 1)."""
    w = _rand(64, 16)
    s = jnp.asarray(RNG.uniform(0.5, 2.0, (16,)).astype(np.float32))
    gl, bl = _rand(2, 16), _rand(2, 16)
    a = ref.fake_quant_lwc(w / s[None, :], gl, bl, 4, 32)
    b = ref.fake_quant_lwc(w, gl, bl, 4, 32) / s[None, :]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# act_quant
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([1, 7, 8, 24, 64]),
    c=st.sampled_from([16, 100, 128]),
    bits=st.integers(min_value=2, max_value=8),
)
def test_act_quant_matches_ref(t, c, bits):
    x = _rand(t, c) * 2.0
    a = ref.act_quant(x, bits)
    b = act_quant.act_quant(x, bits)
    xn = np.asarray(x)
    step = ((xn.max(-1) - xn.min(-1)) / (2.0**bits - 1))[:, None]
    assert_quant_close(a, b, step)


def test_act_quant_higher_rank():
    x = _rand(2, 4, 8, 32)
    a = ref.act_quant(x, 4)
    b = act_quant.act_quant(x, 4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_act_quant_a16_noop():
    x = _rand(8, 32)
    assert np.asarray(act_quant.act_quant(x, 16) == x).all()


def test_act_quant_per_token_independent():
    """Quantizing a batch equals quantizing each token separately."""
    x = _rand(6, 40)
    full = np.asarray(ref.act_quant(x, 4))
    rows = np.stack([np.asarray(ref.act_quant(x[i:i + 1], 4))[0] for i in range(6)])
    np.testing.assert_allclose(full, rows, atol=1e-6)


def test_act_quant_grads_are_ste():
    x = _rand(8, 32)
    g = jax.grad(lambda a: jnp.sum(act_quant.act_quant(a, 4) ** 2))(x)
    gr = jax.grad(lambda a: jnp.sum(ref.act_quant(a, 4) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-5)


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    t=st.sampled_from([8, 24]),
    k=st.sampled_from([64, 96]),
    n=st.sampled_from([32, 128]),
    abits=st.sampled_from([4, 8]),
    wbits=st.sampled_from([2, 4]),
    group=st.sampled_from([0, 32]),
)
def test_qmatmul_matches_ref(t, k, n, abits, wbits, group):
    x, w = _rand(t, k), _rand(k, n)
    a = ref.qmatmul(x, w, abits, wbits, group)
    b = qmatmul.qmatmul(x, w, abits, wbits, group)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4)


def test_qmatmul_approaches_exact_with_bits():
    x, w = _rand(16, 64), _rand(64, 32)
    exact = np.asarray(x @ w)
    e8 = np.abs(np.asarray(qmatmul.qmatmul(x, w, 8, 8, 0)) - exact).max()
    e2 = np.abs(np.asarray(qmatmul.qmatmul(x, w, 2, 2, 0)) - exact).max()
    assert e8 < e2 / 4
    assert e8 < 1.0
