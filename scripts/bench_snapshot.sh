#!/usr/bin/env bash
# Deterministic serving benchmark snapshot.
#
# Runs the sequential / lockstep / continuous serve suite on a synthetic
# quantized model (no artifacts or PJRT needed) — the continuous mode is
# swept over the three KV-store backends (slab / paged / paged-q8) at
# equal token capacity, over 1/2/4 worker threads, over prefill chunk
# sizes under concurrent long-prompt arrivals (step-p90 / TTFT-p90 deltas
# of chunked vs whole-prompt prefill), and over a long-context attention
# sweep at cached lengths {256, 1024, 4096} x kv x threads {1, 4} — one
# warmed cache per point, rewound between kernels — measuring the flash
# single-pass online-softmax path against the two-pass fused stream and
# the gather baseline (attn_sweep / the paged-q8 ctx-4096 t4 headline
# step_p90_improvement_flash_vs_fused, plus _flash_vs_gather and
# _fused_vs_gather / attn_share; every continuous summary also records
# per-tick gemm/attn/sample phase
# timings), plus a trace-overhead check rerunning the slab continuous
# point with the span recorder enabled (step_p90_ms_trace_off /
# step_p90_ms_trace_on / trace_overhead_pct — the < 5% observability
# budget), plus a bursty mixed-length overload trace (4x oversubscribed
# slots, three priority classes, bounded queue) reporting per-class SLO
# attainment and the lifecycle counters (overload_slo_class0/1/2,
# overload_shed / _deadline_exceeded / _preempted / _resumed) — and
# writes the machine-readable BENCH_serve.json at the
# repo root, plus results/serve-bench.md. Pass extra flags through to
# `repro` (e.g. drop --quick for the bigger model).
#
#   scripts/bench_snapshot.sh            # quick snapshot (default)
#   scripts/bench_snapshot.sh --full     # full-size model
set -euo pipefail
cd "$(dirname "$0")/.."

QUICK="--quick"
for arg in "$@"; do
  if [ "$arg" = "--full" ]; then
    QUICK=""
  fi
done

# Preflight: a drifted tree (flag/TOML/JSON surface parity, stale
# allows, kernel invariants — see docs/INVARIANTS.md) must not produce
# a bench snapshot. Exit 1 = findings, 2 = lint internal error.
cargo run --quiet --release --manifest-path rust/Cargo.toml -- lint rust

cargo run --quiet --release --manifest-path rust/Cargo.toml -- \
  repro --exp serve-bench $QUICK

echo "snapshot: $(pwd)/BENCH_serve.json"
